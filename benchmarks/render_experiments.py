"""Inject the generated roofline tables into EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "artifacts" / "dryrun"

NOTES = {
    "compute_s": "raise MXU utilization (bigger tiles, fuse small ops)",
    "memory_s": "cut HBM traffic (flash/fused kernels, remat trades)",
    "collective_s": "cut ICI bytes (dispatch locality, overlap reduce)",
}


def useful_ratio(r: dict) -> float:
    if r.get("kind") == "bpmf":
        return r.get("useful_flops_ratio", 0.0)
    try:
        from repro.configs import get_config
        from repro.models import shape_by_name
        from repro.models.api import model_flops_per_step

        mf = model_flops_per_step(get_config(r["arch"]), shape_by_name(r["shape"]))
        return mf / max(r["per_device_flops"] * r["n_devices"], 1.0)
    except Exception:
        return r.get("useful_flops_ratio", 0.0)


def load(suffix: str) -> list[dict]:
    out = []
    for f in sorted(ART.glob(f"*__{suffix}.json")):
        r = json.loads(f.read_text())
        if r.get("ok"):
            out.append(r)
    return out


def table(recs: list[dict], title: str) -> str:
    lines = [
        f"**{title}**",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant | frac | useful | bottleneck lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant'].replace('_s','')} "
            f"| {t['roofline_fraction']:.3f} | {useful_ratio(r):.3f} "
            f"| {NOTES[t['dominant']]} |"
        )
    return "\n".join(lines) + "\n"


def multi_summary() -> str:
    singles = {(r["arch"], r["shape"]): r for r in load("single")}
    lines = [
        "**Multi-pod (2×16×16 = 512 chips) deltas vs single-pod** — pod axis = pure DP; "
        "the step bound changes only through per-device batch halving and the cross-pod reduce:",
        "",
        "| arch | shape | single bound s | multi bound s | multi coll s |",
        "|---|---|---|---|---|",
    ]
    for r in sorted(load("multi"), key=lambda r: (r["arch"], r["shape"])):
        s = singles.get((r["arch"], r["shape"]))
        if not s:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {s['roofline']['step_lower_bound_s']:.3f} "
            f"| {r['roofline']['step_lower_bound_s']:.3f} | {r['roofline']['collective_s']:.3f} |"
        )
    return "\n".join(lines) + "\n"


def main():
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    base = table(load("single"), "Baseline, single-pod 16×16 (256 chips)") + "\n" + multi_summary()
    opt = table(load("single__opt"), "Optimized variant (`--variant opt`), single-pod")
    text = text.replace("<!-- ROOFLINE_TABLE -->", base)
    text = text.replace("<!-- OPT_TABLE -->", opt)
    exp.write_text(text)
    print(f"rendered {len(load('single'))} baseline + {len(load('single__opt'))} optimized cells")


if __name__ == "__main__":
    main()
