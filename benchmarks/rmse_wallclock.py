"""RMSE-vs-wallclock: minibatch SGLD vs exact fused-Gibbs training.

    PYTHONPATH=src python benchmarks/rmse_wallclock.py [--smoke]

The headline evidence for the SGLD engine (core/sgld.py): exact Gibbs
pays O(|ratings| * K^2) per sweep, SGLD pays O(|minibatch| * K) per step,
so as the dataset grows the exact engine's FLOOR cost — the wallclock of
one full sweep, before which it produces nothing at all — moves right
linearly while SGLD's progress rate stays fixed. Three sections, all
written to BENCH_rmse_wallclock.json (curves included) and summarized
into the committed BENCH_history.jsonl by `run.py --smoke`:

  default profile   a synthetic split the model genuinely learns (the
                    chembl_like scales the other suites use for THROUGHPUT
                    don't separate any trainer from the predict-the-mean
                    baseline, which would make accuracy curves vacuous).
                    Gate: SGLD's converged posterior-mean RMSE within
                    ACCURACY_GAP of fused Gibbs' (accuracy parity — the
                    minibatch noise and finite step size cost ~nothing).
  big profile       >=4x the ratings at serving-scale K, where exact
                    sweeps are the bottleneck. Gate: at the equal-wallclock
                    budget T1 = the time fused Gibbs needs to complete its
                    FIRST sweep (the exact engine's floor cost — budgets
                    below it get no exact estimate whatsoever), SGLD's
                    best RMSE is STRICTLY better than Gibbs'. The summary
                    also reports t_cross, the largest budget at which SGLD
                    still leads — the window [0, t_cross] where the
                    minibatch engine dominates, which widens as |ratings|
                    grows. At CPU-smoke scale exact Gibbs wins at large
                    budgets (its per-rating fused kernel is extremely
                    efficient); the decoupling claim is about the floor,
                    not the asymptote.
  flat iterations   fixed (m, n) and minibatch while nnz grows 1x -> 4x:
                    SGLD per-step wallclock must stay flat
                    (< FLAT_RATIO growth) while the Gibbs sweep time is
                    measured alongside to show the O(|ratings|) contrast.

Timing protocol: one throwaway compiled step before each run, then
cumulative wallclock over chain steps only — RMSE evaluation happens off
the clock. Curve points carry the posterior-mean RMSE once the
accumulator has draws (post burn-in), the current-sample RMSE before.
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import numpy as np

try:
    from benchmarks.common import csv_row, time_fn, write_bench_json
except ModuleNotFoundError:  # invoked as a file: python benchmarks/<name>.py
    import pathlib
    import sys

    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.common import csv_row, time_fn, write_bench_json

from repro.core import GibbsSampler, SGLDSampler
from repro.data import synthetic_lowrank, train_test_split

ALPHA = 4.0
ACCURACY_GAP = 0.05    # default profile: sgld within this of fused Gibbs
FLAT_RATIO = 1.35      # flat-iteration gate: t_step(4x nnz) / t_step(1x)


def _rmse(sampler, state) -> float:
    if int(state.pred_count) == 0:     # pre-burn-in: rmse() would return
        return sampler.sample_rmse(state)   # the predict-the-mean baseline
    r = sampler.rmse(state)
    return sampler.sample_rmse(state) if math.isnan(r) else r


def _curve(sampler, n_steps: int, eval_every: int, seed: int = 0):
    """[(cumulative wall seconds, rmse)] with eval off the clock."""
    state = sampler.init(seed)
    jax.block_until_ready(sampler.sweep(state).u)   # compile, excluded
    state = sampler.init(seed)
    t_cum, pts = 0.0, []
    for i in range(n_steps):
        t0 = time.perf_counter()
        state = sampler.sweep(state)
        jax.block_until_ready(state.u)
        t_cum += time.perf_counter() - t0
        if (i + 1) % eval_every == 0 or i == n_steps - 1:
            pts.append((t_cum, _rmse(sampler, state)))
    return pts


def _best_by(pts, budget: float) -> float:
    """Best RMSE achieved within the wallclock budget (inf if none yet)."""
    vals = [r for t, r in pts if t <= budget]
    return min(vals) if vals else float("inf")


def _t_cross(g_pts, s_pts) -> float:
    """Largest budget at which SGLD's best-so-far still beats Gibbs'."""
    budgets = sorted({t for t, _ in g_pts} | {t for t, _ in s_pts})
    lead = [t for t in budgets if _best_by(s_pts, t) < _best_by(g_pts, t)]
    return max(lead) if lead else 0.0


def _profile(tag, shape, *, k, gibbs_sweeps, gibbs_burn, sgld_steps,
             sgld_burn, eval_every, sgld_kwargs):
    m, n, nnz = shape
    ratings, _, _ = synthetic_lowrank(
        m, n, 8, nnz, noise=0.25, popularity_exponent=1.2, seed=0
    )
    train, test = train_test_split(ratings, 0.1, seed=1)
    print(f"# {tag}: m={train.shape[0]} n={train.shape[1]} nnz={train.nnz}"
          f" k={k}")

    g = GibbsSampler(train, test, k=k, alpha=ALPHA, burn_in=gibbs_burn,
                     engine="fused")
    g_pts = _curve(g, gibbs_sweeps, 1)
    s = SGLDSampler(train, test, k=k, alpha=ALPHA, burn_in=sgld_burn,
                    temp_warmup=sgld_burn, hyper_every=5, accum_every=5,
                    **sgld_kwargs)
    s_pts = _curve(s, sgld_steps, eval_every)

    g_total, g_final = g_pts[-1]
    s_total, s_final = s_pts[-1]
    # equal-wallclock budget: the exact engine's floor cost (first sweep)
    t1, g1 = g_pts[0]
    rows = [
        csv_row(f"rw_{tag}_gibbs_fused", g_total * 1e6 / gibbs_sweeps,
                f"final_rmse={g_final:.4f} total_s={g_total:.2f}"),
        csv_row(f"rw_{tag}_sgld", s_total * 1e6 / sgld_steps,
                f"final_rmse={s_final:.4f} total_s={s_total:.2f}"),
        csv_row(f"rw_{tag}_at_first_sweep", t1 * 1e6,
                f"gibbs={g1:.4f} sgld={_best_by(s_pts, t1):.4f} "
                f"t_cross_s={_t_cross(g_pts, s_pts):.2f}"),
    ]
    summary = {
        "gibbs_curve": [[round(t, 4), round(r, 5)] for t, r in g_pts],
        "sgld_curve": [[round(t, 4), round(r, 5)] for t, r in s_pts],
        "gibbs_final": g_final, "sgld_final": s_final,
        "first_sweep_s": t1, "gibbs_first_sweep": g1,
        "sgld_at_first_sweep": _best_by(s_pts, t1),
        "t_cross_s": _t_cross(g_pts, s_pts),
    }
    return rows, summary


def _flat_study(*, m, n, base_nnz, minibatch, iters):
    """Per-step wallclock vs rating count at fixed (m, n, minibatch)."""
    rows, steps = [], {}
    for mult in (1, 2, 4):
        ratings, _, _ = synthetic_lowrank(
            m, n, 8, base_nnz * mult, noise=0.3, seed=0
        )
        s = SGLDSampler(ratings, None, k=16, alpha=ALPHA,
                        minibatch=minibatch)
        t_s = time_fn(s._sweep, s.init(0), warmup=1, iters=iters)
        g = GibbsSampler(ratings, None, k=16, alpha=ALPHA, engine="fused")
        t_g = time_fn(g._sweep, g.init(0), warmup=1, iters=iters)
        steps[mult] = (t_s, t_g)
        rows.append(csv_row(
            f"rw_flat_{mult}x", t_s * 1e6,
            f"nnz={ratings.nnz} gibbs_sweep_us={t_g * 1e6:.1f}"
        ))
    ratio = steps[4][0] / steps[1][0]
    gibbs_ratio = steps[4][1] / steps[1][1]
    rows.append(csv_row(
        "rw_flat_ratio_4x_over_1x", 0.0,
        f"sgld={ratio:.2f} gibbs={gibbs_ratio:.2f}"
    ))
    return rows, {"sgld_step_ratio": ratio, "gibbs_sweep_ratio": gibbs_ratio}


def main(smoke: bool = False) -> list[str]:
    # the SGLD recipe for accuracy curves: aggressive preconditioned-SGD
    # warmup (temperature annealed over burn-in, trust-region clip 6) with
    # a 1/t step decay reaching sampling-size steps by warmup's end
    recipe = dict(step_size=1.0, step_decay=1.0, step_t0=50.0, clip=6.0)
    if smoke:
        default = dict(shape=(1000, 300, 20000), k=16, gibbs_sweeps=16,
                       gibbs_burn=5, sgld_steps=500, sgld_burn=250,
                       eval_every=20,
                       sgld_kwargs=dict(minibatch=2048, **recipe))
        big = dict(shape=(8000, 1200, 2000000), k=64, gibbs_sweeps=5,
                   gibbs_burn=2, sgld_steps=800, sgld_burn=400,
                   eval_every=25,
                   sgld_kwargs=dict(minibatch=16384, **recipe))
        flat = dict(m=1000, n=300, base_nnz=15000, minibatch=2048, iters=3)
    else:
        default = dict(shape=(2000, 400, 60000), k=32, gibbs_sweeps=40,
                       gibbs_burn=6, sgld_steps=1200, sgld_burn=400,
                       eval_every=25,
                       sgld_kwargs=dict(minibatch=4096, **recipe))
        big = dict(shape=(12000, 1500, 3000000), k=64, gibbs_sweeps=8,
                   gibbs_burn=3, sgld_steps=1200, sgld_burn=600,
                   eval_every=50,
                   sgld_kwargs=dict(minibatch=16384, **recipe))
        flat = dict(m=3000, n=500, base_nnz=60000, minibatch=4096, iters=5)

    rows, extra = [], {}
    d_rows, d_sum = _profile("default", **default)
    rows += d_rows
    extra["default"] = d_sum
    b_rows, b_sum = _profile("big", **big)
    rows += b_rows
    extra["big"] = b_sum
    f_rows, f_sum = _flat_study(**flat)
    rows += f_rows
    extra["flat"] = f_sum

    # acceptance gates (warn, never raise: benchmarks report, CI gates on
    # the committed history trajectory)
    gap = d_sum["sgld_final"] - d_sum["gibbs_final"]
    gates = {
        "accuracy_gap": round(gap, 4),
        "accuracy_ok": bool(gap <= ACCURACY_GAP),
        "big_equal_wallclock_ok": bool(
            b_sum["sgld_at_first_sweep"] < b_sum["gibbs_first_sweep"]
        ),
        "flat_ok": bool(f_sum["sgld_step_ratio"] < FLAT_RATIO),
    }
    extra["gates"] = gates
    rows.append(csv_row(
        "rw_gates", 0.0,
        f"accuracy_gap={gap:+.4f}(<= {ACCURACY_GAP}: {gates['accuracy_ok']}) "
        f"big_equal_wallclock={gates['big_equal_wallclock_ok']} "
        f"flat={gates['flat_ok']}"
    ))
    for name, ok in (("accuracy", gates["accuracy_ok"]),
                     ("big_equal_wallclock", gates["big_equal_wallclock_ok"]),
                     ("flat_iteration", gates["flat_ok"])):
        if not ok:
            print(f"# WARNING: rmse_wallclock gate '{name}' failed")

    path = write_bench_json("rmse_wallclock", rows, extra=extra)
    print(f"# wrote {path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes/steps for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in main(smoke=args.smoke):
        print(row)
