"""Quickstart: BPMF on a synthetic ChEMBL-like dataset, single host.

    PYTHONPATH=src python examples/quickstart.py

Builds a power-law rating matrix, runs the bucketed Gibbs sampler, prints
posterior-mean test RMSE vs the ALS baseline (paper Secs 2-3, 5.2).
"""
import time

from repro.core import ALS, GibbsSampler
from repro.data import chembl_like, train_test_split


def main():
    ratings, _, _ = chembl_like(scale=0.01, seed=0)
    train, test = train_test_split(ratings, test_frac=0.1, seed=1)
    print(f"dataset: {train.shape[0]} x {train.shape[1]}, {train.nnz} train ratings")

    sampler = GibbsSampler(train, test, k=32, alpha=2.0, burn_in=8)
    print("bucket plan:", sampler.user_plan_host.stats())

    t0 = time.time()
    state = sampler.run(30, seed=0, verbose=True)
    n_updates = (train.shape[0] + train.shape[1]) * 30
    dt = time.time() - t0
    print(f"\nBPMF posterior-mean RMSE: {sampler.rmse(state):.4f}")
    print(f"throughput: {n_updates / dt:,.0f} item updates/sec (paper Fig 4 metric)")

    als = ALS(train, test, k=32, lam_reg=0.1)
    a = als.run(12)
    print(f"ALS baseline RMSE:        {als.rmse(a):.4f} (untuned lambda)")


if __name__ == "__main__":
    main()
