"""End-to-end driver: train a ~100M-class LM for a few hundred steps with the
fault-tolerant trainer (checkpoint/restart + failure injection + resume).

    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m --steps 300

Uses a width-reduced config sized for a single CPU device; the same
train_step lowers unchanged onto the 16x16 / 2x16x16 production meshes
(launch/dryrun.py).
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.launch.train import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: /tmp/repro_train_lm_<arch> (resume requires a matching config)")
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the step at 1/3 and 2/3 of the run to demo recovery")
    args = ap.parse_args()
    if args.ckpt_dir is None:
        args.ckpt_dir = f"/tmp/repro_train_lm_{args.arch}"

    cfg = get_config(args.arch)
    # ~100M-class: trim depth/width but keep the architecture family intact
    kv = max(d for d in (1, 2, 4, 8) if d <= max(cfg.n_kv_heads, 1))
    cfg = dataclasses.replace(
        cfg, n_layers=max(2, cfg.n_layers // 4), d_model=512,
        n_heads=8, n_kv_heads=kv, head_dim=64,
        d_ff=1024 if cfg.d_ff else 0, vocab_size=min(cfg.vocab_size, 16_384),
        remat=False, chunked_attn_min_len=1 << 30,
    )
    opt = AdamWConfig(lr=1e-3)
    state = init_train_state(cfg, jax.random.PRNGKey(0), opt)
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    step_fn = jax.jit(make_train_step(cfg, opt, total_steps=args.steps))
    data = TokenStream(cfg, batch=args.batch, seq=args.seq)
    fails = (args.steps // 3, 2 * args.steps // 3) if args.inject_failure else ()
    trainer = Trainer(
        step_fn, state, data,
        TrainerConfig(
            ckpt_dir=args.ckpt_dir,
            ckpt_every=max(10, args.steps // 10),
            fail_at_steps=fails,
        ),
    )
    out = trainer.run(args.steps, log_every=25)
    print(f"final step {out['final_step']}, recoveries {out['recoveries']}, "
          f"loss {out['loss_history'][0]:.3f} -> {out['loss_history'][-1]:.3f}")


if __name__ == "__main__":
    main()
