"""Distributed BPMF: ring (async) vs all-gather (sync) on 8 simulated devices.

    PYTHONPATH=src python examples/distributed_bpmf.py

Reproduces the paper's Sec 4/5 comparison at laptop scale: both samplers
produce identical samples (shared per-item noise), the ring pipelines its
communication behind the syrk batches (Fig 6's "both" region), the sync
version all-gathers up front.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402

from repro.core.distributed import DistributedBPMF  # noqa: E402
from repro.data import chembl_like, train_test_split  # noqa: E402


def main():
    ratings, _, _ = chembl_like(scale=0.005, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)
    print(f"devices: {len(jax.devices())}; "
          f"dataset {train.shape[0]} x {train.shape[1]}, {train.nnz} ratings")

    results = {}
    for mode in ("ring", "allgather"):
        s = DistributedBPMF(train, test, k=32, alpha=2.0, mode=mode)
        st = s.init(0)
        st = s.sweep(st)
        jax.block_until_ready(st.u)  # compile + warm
        t0 = time.time()
        for _ in range(10):
            st = s.sweep(st)
        jax.block_until_ready(st.u)
        dt = (time.time() - t0) / 10
        results[mode] = (dt, s.rmse(st))
        n_items = train.shape[0] + train.shape[1]
        print(f"{mode:10s} sweep {dt*1e3:7.1f} ms  "
              f"({n_items/dt:,.0f} updates/s)  rmse {results[mode][1]:.4f}")
    assert abs(results["ring"][1] - results["allgather"][1]) < 1e-3, \
        "ring and sync must sample identically (paper Sec 5.2 parity)"
    print("accuracy parity OK — ring == allgather samples")


if __name__ == "__main__":
    main()
