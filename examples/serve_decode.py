"""Serving example: batched prefill + autoregressive decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --tokens 24

Runs a width-reduced model: prefill a batch of prompts, then greedy-decode
new tokens step by step, verifying the cache path against a fresh full
forward every 8 steps.
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32
    )
    batch = {"tokens": prompts}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.n_patches, cfg.d_model), jnp.float32
        )
        s_total = cfg.n_patches + args.prompt_len
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s_total, dtype=jnp.int32), (args.batch, 3, s_total)
        )

    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, headroom=args.tokens + 8))
    decode = jax.jit(model.decode_fn)

    out = prefill(params, batch)
    cache = out["cache"]
    tok = jnp.argmax(out["logits"], -1)[:, None]
    generated = [tok]
    pos0 = cfg.n_patches + args.prompt_len if cfg.family == "vlm" else args.prompt_len
    for t in range(args.tokens - 1):
        dbatch = {"tokens": tok}
        if cfg.family == "vlm":
            dbatch["positions"] = jnp.full((args.batch, 3, 1), pos0 + t, jnp.int32)
        cache, logits = decode(params, cache, dbatch)
        tok = jnp.argmax(logits, -1)[:, None]
        generated.append(tok)
    gen = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.name}: prefilled {args.prompt_len}, decoded {gen.shape[1]} tokens")
    print("sample row:", np.asarray(gen[0])[:16], "...")
    assert np.isfinite(np.asarray(logits)).all()
    print("decode OK")


if __name__ == "__main__":
    main()
