"""Train -> checkpoint -> serve: BPMF top-10 recommendations on CPU.

    PYTHONPATH=src python examples/recommend.py

Trains a small MovieLens-shaped BPMF model, retains post-burn-in Gibbs
samples through the checkpoint SampleStore, loads them back as a
PosteriorEnsemble, and serves top-10 recommendations for a batch of trained
users plus one cold-start user folded in from ratings alone. Scores carry
posterior uncertainty (predictive std) — the thing a point-estimate
factorization cannot give you.
"""
import tempfile

import numpy as np

from repro.checkpoint import SampleStore
from repro.core import GibbsSampler
from repro.data import movielens_like, train_test_split
from repro.data.sparse import SparseRatings
from repro.serve import (
    FoldInPlanCache,
    PosteriorEnsemble,
    TopNRecommender,
    fold_in,
)

TOPK = 10


def main():
    ratings, u_true, v_true = movielens_like(scale=0.003, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)
    print(f"dataset {train.shape[0]} x {train.shape[1]}, {train.nnz} ratings")

    # --- train, retaining post-burn-in draws through the checkpoint store ---
    sample_dir = tempfile.mkdtemp(prefix="bpmf_samples_")
    store = SampleStore(sample_dir, keep=8)
    sampler = GibbsSampler(train, test, k=16, alpha=4.0, burn_in=8,
                           widths=(8, 32, 128))
    state = sampler.run(18, seed=0, store=store, verbose=True)
    print(f"test rmse {sampler.rmse(state):.4f}; "
          f"retained {len(store.steps())} samples -> {sample_dir}")

    # --- serve from the retained samples alone (no trainer state) ---
    ens = PosteriorEnsemble.load(sample_dir)
    rec = TopNRecommender(ens)
    users = np.asarray([0, 1, 2, 3], np.int32)
    vals, idx = rec.recommend(users, TOPK, seen=train)
    for r, u in enumerate(users):
        _, var = ens.score(
            np.full(TOPK, u, np.int32), np.maximum(idx[r], 0))
        std = np.sqrt(np.asarray(var))
        top = ", ".join(
            f"{i}({v:.2f}±{s:.2f})" for i, v, s in zip(idx[r][:5], vals[r], std)
        )
        print(f"user {u:4d} top-{TOPK}: {top}, ...")

    # --- cold-start: brand-new users, folded in from ratings alone. All S
    # retained draws are solved in one fused (S*B) batched Cholesky solve,
    # and the plan cache keys the bucket plan's quantized rating-count
    # profile so repeated batches reuse every compiled executable. ---
    rng = np.random.default_rng(7)
    cache = FoldInPlanCache()
    n_rated = 30

    def cold_user():
        rated = rng.choice(train.shape[1], n_rated, replace=False).astype(np.int32)
        u_new = rng.normal(0.0, 1.0 / np.sqrt(u_true.shape[1]), u_true.shape[1])
        r_new = (v_true[rated] @ u_new + rng.normal(0, 0.3, n_rated)).astype(np.float32)
        return rated, SparseRatings(rows=np.zeros(n_rated, np.int32), cols=rated,
                                    vals=r_new, shape=(1, train.shape[1]))

    rated, cold = cold_user()
    # deterministic conditional posterior means (key=None); pass a PRNG key
    # with sample=True for conditional draws instead
    u_draws = fold_in(None, cold, ens, sample=False, plan_cache=cache)
    cvals, cidx = rec.recommend_factors(u_draws, TOPK, exclude=[rated])
    print(f"cold-start user ({n_rated} ratings) top-{TOPK}: "
          + ", ".join(f"{i}({v:.2f})" for i, v in zip(cidx[0], cvals[0])))

    # a second same-profile batch is a plan-cache hit: no replanning, no
    # recompile — the steady state of a cold-start request stream
    rated, cold = cold_user()
    fold_in(None, cold, ens, sample=False, plan_cache=cache)
    print(f"fold-in plan cache after 2 same-profile batches: {cache.stats()}")


if __name__ == "__main__":
    main()
