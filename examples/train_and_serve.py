"""Train while serving: async sample publication, no disk poll.

    PYTHONPATH=src python examples/train_and_serve.py

One process, two roles. A trainer thread runs the BPMF Gibbs chain and
*publishes* every retained post-burn-in draw into a PublicationChannel
(it also writes each draw durably through the SampleStore — push and
durable paths run side by side). The main thread serves top-10
recommendations the whole time: the frontend's subscriber thread adopts
each publish as it lands, swapping the posterior ensemble atomically and
reusing the compiled top-N kernel whenever the ensemble shapes are
unchanged. Requests never wait on a swap, swaps never wait on requests —
the overlap of computation and communication the paper builds distributed
BPMF around (Sec 4), applied to the train -> serve hand-off.

Watch the epoch column: recommendations get fresher as the chain runs,
without the server ever touching the checkpoint directory.
"""
import tempfile
import threading
import time

import numpy as np

from repro.checkpoint import SampleStore
from repro.core import GibbsSampler
from repro.data import movielens_like, train_test_split
from repro.serve import PublicationChannel, RecommendFrontend

SWEEPS = 40
BURN_IN = 6
WINDOW = 4
TOPK = 10
MAX_BATCH = 8


def main():
    ratings, _, _ = movielens_like(scale=0.005, seed=0)
    train, test = train_test_split(ratings, 0.1, seed=1)
    print(f"dataset {train.shape[0]} x {train.shape[1]}, {train.nnz} ratings")

    # the async seam: trainer publishes retained draws, server subscribes
    channel = PublicationChannel(window=WINDOW)
    store = SampleStore(tempfile.mkdtemp(prefix="bpmf_samples_"), keep=WINDOW)
    sampler = GibbsSampler(train, test, k=16, alpha=4.0, burn_in=BURN_IN,
                           widths=(8, 32, 128))

    trainer_error = []

    def train_loop():
        try:
            sampler.run(SWEEPS, seed=0, store=store, publish=channel)
        except BaseException as e:  # noqa: BLE001 — re-raised after join
            trainer_error.append(e)
        finally:
            channel.close()  # end-of-stream: serving loop drains and exits

    trainer = threading.Thread(target=train_loop, name="gibbs-trainer")
    trainer.start()

    # blocks until the first post-burn-in draw is published, then serves
    # continuously; a daemon thread adopts every later publish in-memory
    try:
        frontend = RecommendFrontend(channel=channel, seen=train,
                                     max_batch=MAX_BATCH)
    except Exception:
        trainer.join()  # surface the trainer's failure, not the closed channel
        if trainer_error:
            raise trainer_error[0]
        raise
    print(f"serving from epoch {frontend.epoch} while training continues...")

    rng = np.random.default_rng(0)
    served, last_epoch = 0, None
    while True:
        done = channel.closed and frontend.epoch >= channel.epoch
        for u in rng.integers(0, train.shape[0], MAX_BATCH):
            frontend.submit(int(u), topk=TOPK)
        results = frontend.flush()
        served += len(results)
        for r in results:
            if r.epoch != last_epoch:
                t_pub = channel.publish_time(r.epoch)
                fresh = ""
                if t_pub is not None and last_epoch is not None:
                    fresh = (f"  ({(time.perf_counter() - t_pub) * 1e3:.0f} ms"
                             " after publish)")
                print(f"  now serving epoch {r.epoch}  "
                      f"(top-1: item {r.items[0]}, score {r.scores[0]:.2f})"
                      f"{fresh}")
                last_epoch = r.epoch
        if done:
            break
    trainer.join()
    frontend.close()
    if trainer_error:
        raise trainer_error[0]

    lat = frontend.latency_percentiles()
    print(f"served {served} requests across {frontend.swaps} ensemble swaps "
          f"({frontend.rebinds} reused the compiled top-N kernel); "
          f"request p50 {lat['p50']*1e3:.2f} ms")
    print(f"durable copies of the window: {len(store.steps())} draws in "
          f"{store.store.root} (a restarted server cold-starts from these)")


if __name__ == "__main__":
    main()
